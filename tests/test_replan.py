"""Delta-replan subsystem tests (incremental plan updates + fault path).

Covers:

* ``TrafficMatrix.apply_delta`` — property-checked against a dense
  oracle and against ``from_coo`` on the edited COO stream; exact
  removals; strict ``validate()`` negatives (unsorted / duplicated
  columns, misaligned data).
* ``replan`` — invariants over random edit sequences (table validates,
  edited matrix exactly matches a from-scratch aggregate, level-2
  conservation, untouched bridge rows carried over verbatim).
* ``local_regroup`` — moves confined to the region.
* ``select_bridges`` restricted re-election vs the full election.
* ``evacuate_device`` — dense oracle, load handoff, dead isolation.
* ``Supervisor`` + ``DeviceFailure`` → ``replan_hook`` integration.
* Double-buffered ``PlanBuffer`` swap: bit-identical rasters vs a
  from-scratch rebuild on a 1-D and an (8, 4) mesh, and compiled-step
  reuse when the plan signature is preserved (subprocess, fake devices).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    RoutingTable,
    TrafficMatrix,
    evacuate_device,
    level2_egress,
    local_regroup,
    planted_partition_graph,
    replan,
    select_bridges,
    symmetric_delta,
    two_level_routing,
)
from repro.core.routing import group_pair_traffic
from tests._hypothesis_compat import given, settings, st
from tests.conftest import run_devices

N, G = 64, 8


def _base_tm(n=N, g=G, seed=0) -> TrafficMatrix:
    graph, _ = planted_partition_graph(
        n, n_blocks=g, avg_degree=16, p_in_frac=0.85, seed=seed
    )
    return TrafficMatrix.from_coo(
        graph.rows(), graph.indices, graph.edge_traffic(), n
    ).symmetrized(halve=True)


def _table(n=N, g=G):
    tm = _base_tm(n, g)
    wg = np.ones(n)
    return two_level_routing(tm, wg, g, seed=0), tm, wg


def _random_delta(tm: TrafficMatrix, seed: int, n_edits: int = 12):
    """Mixed edit batch: new pairs, perturbations of stored entries,
    and exact removals (negated stored volumes)."""
    rng = np.random.default_rng(seed)
    n = tm.n_devices
    src = rng.integers(0, n, n_edits).astype(np.int64)
    dst = rng.integers(0, n, n_edits).astype(np.int64)
    vals = rng.uniform(0.1, 2.0, n_edits)
    rows, cols, data = tm.rows(), tm.indices, tm.data
    if rows.size:
        # perturb two stored entries, exactly remove two others
        pick = rng.choice(rows.size, min(4, rows.size), replace=False)
        src = np.concatenate([src, rows[pick]])
        dst = np.concatenate([dst, cols[pick]])
        half = pick.size // 2
        vals = np.concatenate(
            [vals, rng.uniform(0.1, 1.0, pick.size - half), -data[pick[:half]]]
        )
    keep = src != dst
    return src[keep], dst[keep], vals[keep]


def _dense_oracle(tm: TrafficMatrix, src, dst, dvals) -> np.ndarray:
    d = tm.to_dense()
    np.add.at(d, (src, dst), dvals)
    np.fill_diagonal(d, 0.0)
    d[d <= 0] = 0.0
    return d


class TestApplyDelta:
    @given(seed=st.integers(0, 9))
    @settings(max_examples=10, deadline=None)
    def test_matches_dense_oracle(self, seed):
        tm = _base_tm(seed=seed % 3)
        src, dst, dvals = _random_delta(tm, seed)
        got = tm.apply_delta(src, dst, dvals)
        got.validate()
        want = TrafficMatrix.from_dense(_dense_oracle(tm, src, dst, dvals))
        np.testing.assert_array_equal(got.indptr, want.indptr)
        np.testing.assert_array_equal(got.indices, want.indices)
        np.testing.assert_allclose(got.data, want.data, rtol=1e-12, atol=0)

    @given(seed=st.integers(0, 9))
    @settings(max_examples=10, deadline=None)
    def test_matches_from_coo_on_edited_stream(self, seed):
        """apply_delta == re-aggregating the full edited COO stream —
        the edit path never needs the neuron graph again."""
        tm = _base_tm(seed=seed % 3)
        rng = np.random.default_rng(seed)
        src = rng.integers(0, tm.n_devices, 10).astype(np.int64)
        dst = rng.integers(0, tm.n_devices, 10).astype(np.int64)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        dvals = rng.uniform(0.1, 2.0, src.size)  # positive: no removals
        got = tm.apply_delta(src, dst, dvals)
        want = TrafficMatrix.from_coo(
            np.concatenate([tm.rows(), src]),
            np.concatenate([tm.indices, dst]),
            np.concatenate([tm.data, dvals]),
            tm.n_devices,
        )
        np.testing.assert_array_equal(got.indptr, want.indptr)
        np.testing.assert_array_equal(got.indices, want.indices)
        np.testing.assert_allclose(got.data, want.data, rtol=1e-12, atol=0)

    def test_exact_removal_drops_entry(self):
        tm = _base_tm()
        r, c, v = tm.rows()[0], tm.indices[0], tm.data[0]
        got = tm.apply_delta([r], [c], [-v])  # base + (−base) == 0 exactly
        d = got.to_dense()
        assert d[r, c] == 0.0
        assert got.data.size == tm.data.size - 1

    def test_self_loops_dropped(self):
        tm = _base_tm()
        got = tm.apply_delta([3, 1], [3, 2], [5.0, 1.0])
        assert got.to_dense()[3, 3] == 0.0
        assert got.to_dense()[1, 2] == tm.to_dense()[1, 2] + 1.0

    def test_rejects_bad_args(self):
        tm = _base_tm()
        with pytest.raises(ValueError):
            tm.apply_delta([0, 1], [2], [1.0, 1.0])  # length mismatch
        with pytest.raises(ValueError):
            tm.apply_delta([0], [tm.n_devices], [1.0])  # out of range
        with pytest.raises(ValueError):
            tm.apply_delta([-1], [0], [1.0])


class TestValidateStrict:
    def test_unsorted_columns_rejected(self):
        tm = TrafficMatrix(
            indptr=np.array([0, 2, 2, 2], dtype=np.int64),
            indices=np.array([2, 1], dtype=np.int64),
            data=np.array([1.0, 1.0]),
        )
        with pytest.raises(ValueError, match="strictly increasing"):
            tm.validate()

    def test_duplicate_columns_rejected(self):
        tm = TrafficMatrix(
            indptr=np.array([0, 2, 2, 2], dtype=np.int64),
            indices=np.array([1, 1], dtype=np.int64),
            data=np.array([1.0, 1.0]),
        )
        with pytest.raises(ValueError, match="strictly increasing"):
            tm.validate()

    def test_data_length_mismatch_rejected(self):
        tm = TrafficMatrix(
            indptr=np.array([0, 2, 2, 2], dtype=np.int64),
            indices=np.array([1, 2], dtype=np.int64),
            data=np.array([1.0]),
        )
        with pytest.raises(ValueError, match="equal length"):
            tm.validate()

    def test_sorted_matrix_passes(self):
        _base_tm().validate()


class TestSymmetricDelta:
    def test_preserves_symmetry(self):
        tm = _base_tm()
        delta = symmetric_delta([0, 5], [9, 1], [2.0, 0.5])
        d = tm.apply_delta(*delta).to_dense()
        np.testing.assert_allclose(d, d.T, rtol=1e-12)


class TestReplanInvariants:
    @given(seed=st.integers(0, 4))
    @settings(max_examples=5, deadline=None)
    def test_random_edit_sequences(self, seed):
        tb, tm, wg = _table()
        rng = np.random.default_rng(seed)
        for round_i in range(3):
            mem = rng.choice(tb.n_devices, 12, replace=False)
            s = rng.choice(mem, 10)
            d = rng.choice(mem, 10)
            keep = s != d
            delta = symmetric_delta(
                s[keep], d[keep], rng.uniform(0.2, 1.5, int(keep.sum()))
            )
            old_bridge = tb.bridge.copy()
            res = replan(tb, wg, delta)
            res.table.validate()
            # the incrementally edited matrix is exactly the from-scratch
            # aggregate of the edited stream
            tm = tm.apply_delta(*delta)
            got = res.table.device_traffic
            np.testing.assert_array_equal(got.indptr, tm.indptr)
            np.testing.assert_array_equal(got.indices, tm.indices)
            np.testing.assert_allclose(got.data, tm.data, rtol=1e-12, atol=0)
            # conservation: total level-2 bridge egress == total
            # cross-group traffic
            assert np.isclose(
                level2_egress(res.table).sum(),
                group_pair_traffic(res.table).sum(),
                rtol=1e-9,
            )
            # untouched source groups carry their bridge rows verbatim
            untouched = np.setdiff1d(np.arange(G), res.reelected_groups)
            np.testing.assert_array_equal(
                res.table.bridge[untouched], old_bridge[untouched]
            )
            tb = res.table

    def test_empty_delta_is_identity(self):
        tb, _, wg = _table()
        e = np.empty(0, dtype=np.int64)
        res = replan(tb, wg, (e, e, np.empty(0)))
        assert res.moved_devices == 0 and res.reelected_groups.size == 0
        np.testing.assert_array_equal(res.table.bridge, tb.bridge)
        np.testing.assert_array_equal(res.table.group_of, tb.group_of)
        got = res.table.device_traffic
        np.testing.assert_array_equal(got.indices, tb.device_traffic.indices)
        np.testing.assert_array_equal(got.data, tb.device_traffic.data)

    def test_requires_grouped_sparse_table(self):
        tb, tm, wg = _table()
        p2p = RoutingTable(
            group_of=np.arange(N, dtype=np.int64),
            n_groups=N,
            bridge=np.empty((0, 0), dtype=np.int64),
            device_traffic=tm,
            method="p2p",
        )
        e = np.empty(0, dtype=np.int64)
        with pytest.raises(ValueError, match="grouped"):
            replan(p2p, wg, (e, e, np.empty(0)))
        dense = RoutingTable(
            group_of=tb.group_of,
            n_groups=G,
            bridge=tb.bridge,
            device_traffic=tm.to_dense(),
            method=tb.method,
            share_coo=tb.share_coo,
        )
        with pytest.raises(ValueError, match="sparse"):
            replan(dense, wg, (e, e, np.empty(0)))


class TestLocalRegroup:
    def test_outside_region_never_moves(self):
        tb, tm, wg = _table()
        region = np.array([1, 4], dtype=np.int64)
        new, _moves = local_regroup(tm, wg, tb.group_of, region, G)
        outside = ~np.isin(tb.group_of, region)
        np.testing.assert_array_equal(new[outside], tb.group_of[outside])
        assert set(np.unique(new[~outside])) <= set(region.tolist())

    def test_small_region_is_noop(self):
        tb, tm, wg = _table()
        new, moves = local_regroup(
            tm, wg, tb.group_of, np.array([2], dtype=np.int64), G
        )
        assert moves == 0
        np.testing.assert_array_equal(new, tb.group_of)


class TestSelectBridgesRestricted:
    def test_all_groups_equals_full_election(self):
        tb, tm, _ = _table()
        full_b, full_s = select_bridges(tm, tb.group_of, G)
        res_b, res_s = select_bridges(
            tm,
            tb.group_of,
            G,
            only_groups=np.arange(G),
            base=(tb.bridge, tb.share_coo),
        )
        np.testing.assert_array_equal(full_b, res_b)
        want = sorted(zip(*[np.asarray(a).tolist() for a in full_s]))
        got = sorted(zip(*[np.asarray(a).tolist() for a in res_s]))
        assert want == got

    def test_no_groups_returns_base(self):
        tb, tm, _ = _table()
        b, s = select_bridges(
            tm,
            tb.group_of,
            G,
            only_groups=np.empty(0, dtype=np.int64),
            base=(tb.bridge, tb.share_coo),
        )
        np.testing.assert_array_equal(b, tb.bridge)
        want = sorted(zip(*[np.asarray(a).tolist() for a in tb.share_coo]))
        got = sorted(zip(*[np.asarray(a).tolist() for a in s]))
        assert want == got


class TestEvacuateDevice:
    def test_matches_dense_handoff_oracle(self):
        tb, tm, wg = _table()
        dead = int(tb.bridge[tb.bridge >= 0].ravel()[0])
        delta, wg2, host = evacuate_device(tb, wg, dead)
        got = tm.apply_delta(*delta)
        d = tm.to_dense()
        d[host] += d[dead]
        d[:, host] += d[:, dead]
        d[dead], d[:, dead] = 0.0, 0.0
        np.fill_diagonal(d, 0.0)
        np.testing.assert_allclose(got.to_dense(), d, rtol=1e-12, atol=0)
        assert not np.any(got.rows() == dead) and not np.any(got.indices == dead)
        assert wg2[dead] == 0.0 and wg2[host] == wg[host] + wg[dead]
        assert int(tb.group_of[host]) == int(tb.group_of[dead])

    def test_fault_replan_isolates_dead(self):
        tb, _tm, wg = _table()
        dead = int(tb.bridge[tb.bridge >= 0].ravel()[0])
        delta, wg2, _host = evacuate_device(tb, wg, dead)
        res = replan(tb, wg2, delta, dead=[dead])
        res.table.validate()
        tmd = res.table.device_traffic
        assert not np.any(tmd.rows() == dead) and not np.any(tmd.indices == dead)
        assert not np.any(res.table.bridge == dead)

    def test_rejects_host_equal_dead(self):
        tb, _tm, wg = _table()
        with pytest.raises(ValueError, match="differ"):
            evacuate_device(tb, wg, 0, host=0)


class TestSupervisorReplanIntegration:
    def test_device_failure_triggers_replan_hook(self, tmp_path):
        """A DeviceFailure mid-run drives evacuate → replan via the
        supervisor's replan_hook, then training retries from the last
        checkpoint and completes."""
        import jax.numpy as jnp

        from repro.train import DeviceFailure, Supervisor, SupervisorConfig

        tb, _tm, wg = _table()
        state = {"tb": tb, "wg": wg, "replanned": []}

        def replan_hook(device):
            delta, wg2, _host = evacuate_device(state["tb"], state["wg"], device)
            res = replan(state["tb"], wg2, delta, dead=[device])
            state["tb"], state["wg"] = res.table, res.wg
            state["replanned"].append(device)

        dead = int(tb.bridge[tb.bridge >= 0].ravel()[0])
        fired = {"done": False}

        def bomb(step_idx):
            if step_idx == 3 and not fired["done"]:
                fired["done"] = True
                raise DeviceFailure(dead)

        def train_step(params, opt, batch):
            w = params["w"]
            loss = jnp.sum(w * batch)
            return loss, {"w": w - 0.1 * batch}, opt, None

        data = lambda s: jnp.full(4, float(s + 1))
        sup = Supervisor(
            train_step,
            {"w": jnp.zeros(4)},
            {},
            data,
            SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=2),
            failure_hook=bomb,
            replan_hook=replan_hook,
        )
        hist = sup.run(6)
        assert state["replanned"] == [dead]
        assert not np.any(state["tb"].bridge == dead)
        assert any(h.restarted and h.retries == 1 for h in hist)
        assert hist[-1].step == 6


class TestPlanSwapExecution:
    def test_double_buffered_swap_bit_identical(self):
        """Staged+flipped plans replay bit-identically to a from-scratch
        engine on a 1-D and an (8, 4) mesh, and a signature-preserving
        swap reuses the compiled step (cache hit, no new miss)."""
        code = """
import numpy as np, jax
import repro.snn.distributed as dist_mod
from repro.snn import DistributedSNN, LIFParams, BlockSynapses, PlanBuffer
from repro.snn.ragged import build_ragged_plan
from repro.compat import make_mesh
from tests.test_snn_sparse import _clustered_w

params = LIFParams(noise_sigma=0.0)
for n_blocks, mesh_spec in [(8, ((8,), ("data",))), (32, ((8, 4), ("pod", "data")))]:
    w = _clustered_w(64, n_blocks)
    syn = BlockSynapses.from_dense(w, n_blocks)
    mesh = make_mesh(*mesh_spec)
    eng = DistributedSNN(mesh=mesh, params=params, exchange="ragged",
                         i_ext=4.0, syn=syn)
    buf = PlanBuffer(eng)
    r1 = np.asarray(buf.engine.run(20, key=jax.random.PRNGKey(7)))

    # same-signature restage: pointer flip, compiled step reused
    info0 = dist_mod._sparse_step.cache_info()
    assert buf.stage(buf.engine._ragged_plan())
    r1b = np.asarray(buf.flip().run(20, key=jax.random.PRNGKey(7)))
    info1 = dist_mod._sparse_step.cache_info()
    assert np.array_equal(r1, r1b), mesh_spec
    assert info1.misses == info0.misses and info1.hits > info0.hits, mesh_spec

    # edited weights -> new plan; swap == from-scratch rebuild
    b = w.shape[0] // n_blocks
    w2 = w.copy()
    w2[:b, -b:] = 0.3
    w2[-b:, b:2*b] = 0.0
    syn2 = BlockSynapses.from_dense(w2, n_blocks)
    plan2 = build_ragged_plan(syn2, buf.engine.plan.mesh_shape)
    buf.stage(plan2, syn=syn2)
    r_swap = np.asarray(buf.flip().run(20, key=jax.random.PRNGKey(7)))
    fresh = DistributedSNN(mesh=mesh, params=params, exchange="ragged",
                           i_ext=4.0, syn=syn2)
    r_fresh = np.asarray(fresh.run(20, key=jax.random.PRNGKey(7)))
    assert np.array_equal(r_swap, r_fresh), mesh_spec
print("OK")
"""
        assert "OK" in run_devices(code, n_devices=32)


class TestBatchEvacuation:
    def test_batch_matches_sequential_singles(self):
        """One batched call over [d0, d1] == evacuating d0 then d1 by
        hand against the running matrix (delta is additive COO)."""
        from repro.core import evacuate_devices

        tb, tm, wg = _table()
        bridges = np.unique(tb.bridge[tb.bridge >= 0].ravel())
        dead = [int(bridges[0]), int(bridges[-1])]
        ev = evacuate_devices(tb, wg, dead)
        got = tm.apply_delta(*ev.delta)

        d = tm.to_dense()
        for dd, host in zip(ev.dead, ev.hosts):
            d[host] += d[dd]
            d[:, host] += d[:, dd]
            d[dd], d[:, dd] = 0.0, 0.0
            np.fill_diagonal(d, 0.0)
        np.testing.assert_allclose(got.to_dense(), d, rtol=1e-12, atol=0)
        assert np.all(ev.wg_after[ev.dead] == 0.0)
        assert np.all(ev.wg_before == wg)

    def test_dead_pair_flows_internalize_not_dangle(self):
        """Two dead devices that talked to each other: the later
        evacuation must see the re-keyed flow, so nothing still
        references either dead key."""
        from repro.core import evacuate_devices

        tb, tm, wg = _table()
        rows, cols = tm.rows(), tm.indices
        i = int(np.argmax(tm.data))  # a stored pair, both ends dead
        dead = [int(rows[i]), int(cols[i])]
        ev = evacuate_devices(tb, wg, dead)
        got = tm.apply_delta(*ev.delta)
        assert not np.any(np.isin(got.rows(), dead))
        assert not np.any(np.isin(got.indices, dead))
        assert not np.any(np.isin(ev.hosts, dead))

    def test_batch_replan_isolates_all_dead(self):
        from repro.core import evacuate_devices

        tb, _tm, wg = _table()
        dead = [3, 17, 42]
        ev = evacuate_devices(tb, wg, dead)
        res = replan(tb, ev.wg_after, ev.delta, dead=dead)
        res.table.validate()
        tmd = res.table.device_traffic
        assert not np.any(np.isin(tmd.rows(), dead))
        assert not np.any(np.isin(tmd.indices, dead))
        assert not np.any(np.isin(res.table.bridge, dead))

    def test_validation_negatives(self):
        from repro.core import evacuate_devices

        tb, _tm, wg = _table()
        with pytest.raises(ValueError, match="no devices"):
            evacuate_devices(tb, wg, [])
        with pytest.raises(ValueError, match="duplicate"):
            evacuate_devices(tb, wg, [3, 3])
        with pytest.raises(ValueError, match="1:1"):
            evacuate_devices(tb, wg, [3, 4], hosts=[5])
        with pytest.raises(ValueError, match="itself being evacuated"):
            evacuate_devices(tb, wg, [3, 4], hosts=[4, 5])


class TestRejoin:
    def test_rejoin_restores_matrix_bit_exactly(self):
        """evacuate → replan → rejoin: the rejoined traffic matrix is
        BIT-identical to the pre-failure one (indptr, indices, data),
        and the rejoined device weights equal the originals."""
        from repro.core import evacuate_devices, rejoin_devices

        tb, tm, wg = _table()
        bridges = np.unique(tb.bridge[tb.bridge >= 0].ravel())
        dead = [int(bridges[0]), int(bridges[-1])]
        ev = evacuate_devices(tb, wg, dead)
        res = replan(tb, ev.wg_after, ev.delta, dead=dead)

        back = rejoin_devices(res.table, ev)
        back.table.validate()
        tmr = back.table.device_traffic
        assert np.array_equal(tmr.indptr, tm.indptr)
        assert np.array_equal(tmr.indices, tm.indices)
        assert np.array_equal(tmr.data, tm.data)  # bit-equal, not close

    def test_rejoin_restores_same_group_pair(self):
        """The host-internalization edge case: dead and host share a
        group, their mutual flow vanished during evacuation — rejoin
        must resurrect it at the exact stored value."""
        from repro.core import evacuate_devices, rejoin_devices

        tb, tm, wg = _table()
        # pick a stored intra-group pair and force its partner as host
        rows, cols = tm.rows(), tm.indices
        same = np.flatnonzero(tb.group_of[rows] == tb.group_of[cols])
        i = int(same[0])
        dead, host = int(rows[i]), int(cols[i])
        ev = evacuate_devices(tb, wg, [dead], hosts=[host])
        res = replan(tb, ev.wg_after, ev.delta, dead=[dead])
        assert not np.any(np.isin(res.table.device_traffic.rows(), [dead]))

        back = rejoin_devices(res.table, ev)
        tmr = back.table.device_traffic
        assert np.array_equal(tmr.indptr, tm.indptr)
        assert np.array_equal(tmr.indices, tm.indices)
        assert np.array_equal(tmr.data, tm.data)

    def test_rejoined_device_eligible_for_bridge_duty(self):
        """After rejoin no device is barred: the rejoined table's bridge
        matrix may elect the repaired device again (it must at least be
        a valid table with every flow routed)."""
        from repro.core import evacuate_devices, rejoin_devices
        from repro.core.routing import group_pair_traffic

        tb, tm, wg = _table()
        dead = int(tb.bridge[tb.bridge >= 0].ravel()[0])
        ev = evacuate_devices(tb, wg, [dead])
        res = replan(tb, ev.wg_after, ev.delta, dead=[dead])
        back = rejoin_devices(res.table, ev)
        back.table.validate()
        # group-pair traffic equals the pre-failure table's exactly
        np.testing.assert_allclose(
            group_pair_traffic(back.table),
            group_pair_traffic(tb),
            rtol=1e-12,
            atol=0,
        )
