"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "POD_SIZE"]

POD_SIZE = 256  # chips per pod (16 × 16)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
