"""Analytic latency model for the paper's Table II.

The paper measures end-to-end simulation step latency on the Kunlun
supercomputer.  We cannot measure InfiniBand congestion on CPU, so — as
recorded in DESIGN.md §9 — Table II is reproduced with an α-β-congestion
model whose constants are calibrated to the paper's reported cluster
behaviour.  The *inputs* to the model (per-device traffic, connection
counts, bridge loads) come from running the real algorithms on the real
generated graph; only the translation traffic→seconds is analytic.

Model
-----
A simulation step costs::

  T_step = T_compute + T_comm
  T_comm = max_d [ conn(d) · α_conn                    (connection setup:
                                                        one host thread per
                                                        logical connection)
                 + egress(d) / bw_eff(d) ]             (serialization)
  bw_eff(d) = bw_link / (1 + γ · congestion(d))        (congestion collapse)

``congestion(d)`` counts how many *other* flows contend for the links the
device's traffic traverses — with unbalanced traffic and thousands of
simultaneous P2P connections the effective bandwidth collapses, which is
how 1,552-connection random/GA runs take hours while the two-level
schedule takes fractions of a second (Table II rows 1–3).

Channel noise (the paper's complexity knob, 0.1–0.6) raises firing rates
and hence both compute and traffic; we model it as a multiplier
``1 + κ·noise`` on both terms, reproducing Table II's monotone growth.

Two backends, one API
---------------------
:func:`estimate` is the entry point Table-II/Fig-3b consumers call:

* ``model='closed_form'`` — the α-β-congestion formulas in this module
  (:func:`step_latency`), cheap enough for sweeps at any scale.
* ``model='netsim'`` — the discrete-event interconnect simulator
  (:mod:`repro.netsim`): the table's forwarding schedule is replayed
  message by message over an explicit topology, so congestion comes
  from simulated FIFO queueing on shared links instead of the fitted
  ``γ`` term.  Pass ``topology=`` (default: a single switch over the
  table's devices) and the same ``cluster`` constants — ``alpha_conn``
  becomes the per-message injection cost, ``bytes_per_traffic_unit``
  scales flows to wire bytes.

Both return the same :class:`LatencyBreakdown`, so benchmarks flip
between them with a flag.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.routing import (
    RoutingTable,
    connection_counts,
    level1_egress,
    level2_egress,
)

__all__ = [
    "ClusterModel",
    "LatencyBreakdown",
    "estimate",
    "step_latency",
    "table2_row",
]


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Constants calibrated to the paper's cluster (Kunlun, IB + PCIe).

    Attributes:
      alpha_conn: per-logical-connection setup cost (thread launch + QP
        handshake), seconds.  The paper attributes large overheads to the
        one-thread-per-connection model.
      bw_link: per-device egress bandwidth, bytes/second.
      gamma: congestion sensitivity — how fast effective bandwidth
        collapses as contending flows accumulate.
      kappa: channel-noise traffic/compute multiplier.
      t_compute0: base per-step compute time at noise 0, seconds.
      bytes_per_traffic_unit: converts abstract traffic units
        (``P·W_i·W_j``) into wire bytes.
    """

    alpha_conn: float = 2.0e-4
    bw_link: float = 12.5e9  # 100 Gb/s IB EDR per device
    gamma: float = 8.0e-3
    kappa: float = 1.1
    t_compute0: float = 0.04
    bytes_per_traffic_unit: float = 1.0

    def with_noise(self, noise: float) -> "ClusterModel":
        return self


@dataclasses.dataclass(frozen=True)
class LatencyBreakdown:
    t_total: float
    t_compute: float
    t_conn: float
    t_serial: float
    worst_device: int


def _congestion_per_device(tb: RoutingTable) -> np.ndarray:
    """Contending-flow count seen by each device's egress path.

    P2P: every simultaneous connection in the system shares the fabric;
    a device's flows contend with the *fan-in* at their destinations.
    Two-level: only same-group flows plus the aggregated bridge flows
    contend on the relevant links.

    Fully vectorized over the sparse traffic entries (O(nnz) + O(nnz(G²))
    for the bridge term); dense tables are converted on entry.
    """
    from repro.core.traffic import TrafficMatrix

    tm = tb.device_traffic
    if not isinstance(tm, TrafficMatrix):
        tm = TrafficMatrix.from_dense(tm)
    n = tb.n_devices
    rows, cols = tm.rows(), tm.indices  # every stored entry is active (> 0)
    if tb.method == "p2p":
        # fan-in congestion: flows arriving at each of my destinations
        fan_in = np.bincount(cols, minlength=n).astype(np.float64)
        return (
            np.bincount(rows, weights=fan_in[cols], minlength=n)
            - np.bincount(rows, minlength=n)  # others, not me
        )
    # two-level: destinations are same-group peers + served bridges
    intra = tb.group_of[rows] == tb.group_of[cols]
    r_i, c_i = rows[intra], cols[intra]
    fan_in = np.bincount(c_i, minlength=n).astype(np.float64)
    cong = np.bincount(r_i, weights=fan_in[c_i], minlength=n) - np.bincount(
        r_i, minlength=n
    )
    # bridges contend with other bridges targeting the same group: one
    # aggregated flow per source group arriving at gd, charged to *every*
    # bridge carrying a share of the flow (split flows contend too)
    from repro.core.routing import _share_coo_or_primary, group_pair_traffic

    gpt = group_pair_traffic(tb)
    incoming = (gpt > 0).sum(axis=0)
    sdev, sgrp, _ = _share_coo_or_primary(tb)
    served = gpt[tb.group_of[sdev], sgrp] > 0
    np.add.at(
        cong,
        sdev[served],
        np.maximum(0, incoming[sgrp[served]] - 1).astype(np.float64),
    )
    return cong


def step_latency(
    tb: RoutingTable,
    cluster: ClusterModel = ClusterModel(),
    *,
    noise: float = 0.1,
) -> LatencyBreakdown:
    """Latency of one simulation step under routing table ``tb``."""
    noise_mult = 1.0 + cluster.kappa * noise
    conn = connection_counts(tb)
    egress = (level1_egress(tb) + level2_egress(tb)) * noise_mult
    egress_bytes = egress * cluster.bytes_per_traffic_unit
    cong = _congestion_per_device(tb)
    bw_eff = cluster.bw_link / (1.0 + cluster.gamma * cong)
    t_conn = conn * cluster.alpha_conn
    t_serial = egress_bytes / bw_eff
    t_comm = t_conn + t_serial
    worst = int(np.argmax(t_comm))
    t_compute = cluster.t_compute0 * noise_mult
    return LatencyBreakdown(
        t_total=float(t_compute + t_comm[worst]),
        t_compute=float(t_compute),
        t_conn=float(t_conn[worst]),
        t_serial=float(t_serial[worst]),
        worst_device=worst,
    )


def _netsim_latency(
    tb: RoutingTable,
    cluster: ClusterModel,
    *,
    noise: float,
    topology=None,
) -> LatencyBreakdown:
    """Discrete-event backend: replay the table's forwarding schedule.

    Lazy-imports :mod:`repro.netsim` (keeps the closed-form path free of
    the dependency).  The cluster constants map onto the simulator:
    ``alpha_conn`` is charged per message at injection (the host-side
    connection cost that sinks P2P in Table II), traffic units scale to
    wire bytes by ``bytes_per_traffic_unit`` times the noise multiplier.
    """
    from repro import netsim

    topo = topology or netsim.single_switch(tb.n_devices, link_bw=cluster.bw_link)
    if topo.n_devices != tb.n_devices:
        raise ValueError(f"topology has {topo.n_devices} devices, table {tb.n_devices}")
    noise_mult = 1.0 + cluster.kappa * noise
    rounds = netsim.table_rounds(tb, bytes_per_unit=cluster.bytes_per_traffic_unit * noise_mult)
    # forwarding stages truly depend on each other (bridges aggregate
    # only after level-1 delivers) — simulate with barriers
    res = netsim.simulate(rounds, topo, alpha_msg=cluster.alpha_conn, barriers=True)
    res.assert_conserved()
    t_compute = cluster.t_compute0 * noise_mult
    return LatencyBreakdown(
        t_total=float(t_compute + res.t_total),
        t_compute=float(t_compute),
        t_conn=0.0,  # folded into the simulated per-message injection cost
        t_serial=float(res.t_total),
        worst_device=res.worst_device(),
    )


def estimate(
    tb: RoutingTable,
    cluster: ClusterModel = ClusterModel(),
    *,
    model: str = "closed_form",
    noise: float = 0.1,
    topology=None,
) -> LatencyBreakdown:
    """Step-latency estimate under routing table ``tb``, either backend.

    The two backends answer different questions (the PR 5 finding, see
    ``docs/PAPER_MAPPING.md``): ``'closed_form'`` includes the
    per-connection host cost (``alpha_conn``) and the superlinear
    congestion term — the regime where the paper's P2P rows collapse —
    while ``'netsim'`` is a wire-level floor under which P2P is merely
    worse, not catastrophic.

    Args:
      cluster: :class:`ClusterModel` constants (link bandwidth,
        per-connection setup cost, congestion coefficients, unit scale).
      model: ``'closed_form'`` (this module's α-β-congestion formulas)
        or ``'netsim'`` (discrete-event replay over ``topology`` —
        :mod:`repro.netsim`).
      noise: channel-noise level ``z`` of Table II — scales spike (and
        hence wire) volume.
      topology: netsim only — a :class:`repro.netsim.Topology` over the
        table's devices; defaults to a single switch at the cluster's
        link bandwidth.

    Returns:
      :class:`LatencyBreakdown` — per-term seconds plus ``t_total``.
    """
    if model == "closed_form":
        return step_latency(tb, cluster, noise=noise)
    if model == "netsim":
        return _netsim_latency(tb, cluster, noise=noise, topology=topology)
    raise ValueError(f"unknown latency model {model!r}")


def table2_row(
    tb: RoutingTable,
    cluster: ClusterModel = ClusterModel(),
    noises: tuple[float, ...] = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6),
    *,
    model: str = "closed_form",
    topology=None,
) -> list[float]:
    """One row of Table II: step latency across channel-noise levels,
    under either latency backend (see :func:`estimate`)."""
    return [
        estimate(tb, cluster, model=model, noise=z, topology=topology).t_total
        for z in noises
    ]
