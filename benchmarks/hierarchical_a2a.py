"""Hierarchical (two-level) vs flat all-to-all on the TPU mesh —
the paper's §IV-B bridge pattern applied to MoE dispatch / gradient
reduction (DESIGN.md §4).

Two measurements:
  1. Analytic: cross-pod message count + bytes per full exchange on the
     production 2×16×16 mesh (paper Fig. 4 restated: messages drop by
     the group size; bytes stay equal).
  2. Executable: an 8-host-device subprocess runs both schedules via
     shard_map and asserts numerical equality while timing them.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

from repro.core.hierarchical import dispatch_bytes, dispatch_messages
from benchmarks.common import emit

_CHILD = r"""
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.hierarchical import make_exchange_fns
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("pod", "data"))
n_dev, chunk, d = 8, 64, 256
x = jnp.arange(n_dev * n_dev * chunk * d, dtype=jnp.float32).reshape(
    n_dev, n_dev, chunk, d)
x = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
flat, two = make_exchange_fns(mesh)
yf = flat(x); yt = two(x)
np.testing.assert_allclose(np.asarray(yf), np.asarray(yt))
for name, fn in [("flat", flat), ("two_level", two)]:
    fn(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        out = fn(x)
    out.block_until_ready()
    dt = (time.perf_counter() - t0) / 20
    print(f"{name},{dt*1e6:.1f}")
print("equal,1")
"""


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--inner", type=int, default=256)
    ap.add_argument("--chunk-bytes", type=int, default=2 * 320 * 2048)  # qwen3 token block
    ap.add_argument("--skip-exec", action="store_true")
    args = ap.parse_args(argv)

    for two in (False, True):
        tag = "two_level" if two else "flat"
        msgs = dispatch_messages(args.pods, args.inner, two_level=two)
        byts = dispatch_bytes(args.pods, args.inner, args.chunk_bytes, two_level=two)
        emit(f"a2a/{tag}_cross_pod_msgs", msgs["cross_pod"], "per exchange")
        emit(f"a2a/{tag}_cross_pod_bytes", f"{byts['cross_pod']:.3e}", "")
    red = dispatch_messages(args.pods, args.inner, two_level=False)["cross_pod"] / max(
        dispatch_messages(args.pods, args.inner, two_level=True)["cross_pod"], 1
    )
    emit("a2a/msg_reduction_factor", round(red, 1), "= inner group size (paper Fig.4)")

    if not args.skip_exec:
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        out = subprocess.run(
            [sys.executable, "-c", _CHILD], env=env, capture_output=True, text=True
        )
        if out.returncode != 0:
            emit("a2a/exec_equal", 0, out.stderr.strip()[-200:])
        else:
            for line in out.stdout.strip().splitlines():
                k, v = line.split(",")
                emit(f"a2a/exec_{k}_us" if k != "equal" else "a2a/exec_equal", v, "")


if __name__ == "__main__":
    main()
