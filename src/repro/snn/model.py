"""Brain-model generator (the paper's simulation model, §I/§V).

The paper's model is "created according to the biological structure of a
real human brain scanned using medical instruments" — i.e. a parcellation
into regions/populations with empirical connection probabilities, scaled
to 10–20 billion neurons.  We generate the same *class* of model:

* ``n_regions`` cortical regions laid out on a 3-D shell;
* each region holds several neuron **populations** (the partitioning
  granularity — P[M,M] at M = 1e10 single neurons is not materializable,
  see DESIGN.md §9.3);
* connectivity = strong intra-region community structure + distance-
  dependent exponential fall-off between regions + sparse long-range
  fascicles (heavy-tail) — the "extremely sparse, uneven" matrix the
  paper describes;
* population weight = neuron count × firing rate × bytes/spike, i.e. the
  expected traffic the population generates (the paper's ``W``).

The generator is deterministic per seed and scales from unit-test sizes
(tens of populations) to paper scale (10^4–10^5 populations representing
10^10 neurons) in seconds, because everything is vectorized sparse COO.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import CommGraph, build_graph

__all__ = ["BrainModel", "generate_brain_model"]


@dataclasses.dataclass(frozen=True)
class BrainModel:
    """A generated brain model at population granularity.

    Attributes:
      graph: population-level communication graph (P, W).
      neuron_counts: ``int64[n_pop]`` neurons per population.
      region_of: ``int64[n_pop]`` population → region.
      positions: ``float64[n_pop, 3]`` population centroids.
      firing_rate: ``float64[n_pop]`` mean rate (Hz) per population.
      total_neurons: Σ neuron_counts.
    """

    graph: CommGraph
    neuron_counts: np.ndarray
    region_of: np.ndarray
    positions: np.ndarray
    firing_rate: np.ndarray

    @property
    def total_neurons(self) -> int:
        return int(self.neuron_counts.sum())

    @property
    def n_populations(self) -> int:
        return int(self.neuron_counts.shape[0])


def generate_brain_model(
    *,
    n_populations: int = 2048,
    n_regions: int = 90,
    total_neurons: int = 10_000_000_000,
    intra_region_p: float = 0.35,
    lambda_mm: float = 28.0,
    inter_degree: float = 12.0,
    long_range_frac: float = 0.015,
    mean_rate_hz: float = 4.0,
    bytes_per_spike: float = 4.0,
    seed: int = 0,
) -> BrainModel:
    """Generate a brain model.

    Defaults follow the AAL-90 parcellation shape scaled to the paper's
    10-billion-neuron setup.  Region sizes and rates are log-normal
    (biological population sizes are heavy-tailed — the *uneven traffic*
    of the paper's guideline #3 falls out of this).
    """
    rng = np.random.default_rng(seed)
    if n_regions > n_populations:
        raise ValueError("need at least one population per region")

    # --- regions on a spherical shell (cortex-like geometry, mm units)
    u = rng.normal(size=(n_regions, 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    region_pos = u * rng.uniform(60.0, 80.0, size=(n_regions, 1))

    # --- populations per region (log-normal sizes)
    region_of = np.sort(rng.integers(0, n_regions, size=n_populations))
    # guarantee every region non-empty
    region_of[:n_regions] = np.arange(n_regions)
    region_of = np.sort(region_of)
    jitter = rng.normal(scale=4.0, size=(n_populations, 3))
    positions = region_pos[region_of] + jitter

    raw = rng.lognormal(mean=0.0, sigma=0.8, size=n_populations)
    neuron_counts = np.maximum(
        1, np.round(raw / raw.sum() * total_neurons)
    ).astype(np.int64)

    firing_rate = rng.lognormal(
        mean=np.log(mean_rate_hz), sigma=0.5, size=n_populations
    )

    # --- edges -------------------------------------------------------
    # intra-region: dense community block (prob ~ intra_region_p)
    srcs, dsts, ps = [], [], []
    for r in range(n_regions):
        members = np.nonzero(region_of == r)[0]
        k = members.shape[0]
        if k < 2:
            continue
        ii, jj = np.triu_indices(k, 1)
        keep = rng.random(ii.shape[0]) < intra_region_p
        srcs.append(members[ii[keep]])
        dsts.append(members[jj[keep]])
        ps.append(rng.uniform(0.3, 1.0, int(keep.sum())))

    # inter-region: distance-dependent sampling.  Sample candidate pairs
    # proportional to exp(-dist/λ) without materializing the n_pop² grid.
    # ``inter_degree`` targets the mean number of inter-region partners
    # per population — the paper's device graph is dense (mean 1,552
    # connections per GPU at 2,000 GPUs), which requires a rich
    # projection structure, so the candidate count adapts to the target
    # via a pilot estimate of the distance-acceptance rate.
    pilot_i = rng.integers(0, n_populations, size=4096)
    pilot_j = rng.integers(0, n_populations, size=4096)
    pd = np.linalg.norm(positions[pilot_i] - positions[pilot_j], axis=1)
    acc_rate = max(float(np.exp(-pd / lambda_mm).mean()), 1e-4)
    n_cand = int(inter_degree * n_populations / 2 / acc_rate)
    ci = rng.integers(0, n_populations, size=n_cand)
    cj = rng.integers(0, n_populations, size=n_cand)
    valid = (ci != cj) & (region_of[ci] != region_of[cj])
    ci, cj = ci[valid], cj[valid]
    dist = np.linalg.norm(positions[ci] - positions[cj], axis=1)
    accept = rng.random(ci.shape[0]) < np.exp(-dist / lambda_mm)
    srcs.append(ci[accept])
    dsts.append(cj[accept])
    ps.append(rng.uniform(0.05, 0.4, int(accept.sum())))

    # long-range fascicles: few, strong, distance-oblivious
    n_long = max(1, int(long_range_frac * n_populations))
    li = rng.integers(0, n_populations, size=n_long)
    lj = rng.integers(0, n_populations, size=n_long)
    keep = li != lj
    srcs.append(li[keep])
    dsts.append(lj[keep])
    ps.append(rng.uniform(0.4, 0.9, int(keep.sum())))

    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    prob = np.concatenate(ps)

    # paper's W: expected traffic = neurons × rate × bytes/spike
    weights = neuron_counts.astype(np.float64) * firing_rate * bytes_per_spike
    # normalize to keep objectives in a numerically friendly range
    weights = weights / weights.mean()

    graph = build_graph(src, dst, prob, weights, sym=True)
    return BrainModel(
        graph=graph,
        neuron_counts=neuron_counts,
        region_of=region_of,
        positions=positions,
        firing_rate=firing_rate,
    )
