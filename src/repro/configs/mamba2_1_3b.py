"""mamba2-1.3b — 48L d_model=2048 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060; unverified]

Pure SSM stack: d_inner = 2·d_model = 4096, head_dim 64 ⇒ 64 SSD heads,
one B/C group, conv kernel 4.  Mamba-2 blocks have no separate MLP
(d_ff = 0).  Constant state ⇒ long_500k decode runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=("ssm",) * 48,
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    conv_kernel=4,
    source="arXiv:2405.21060; unverified",
)
