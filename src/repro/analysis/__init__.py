"""planlint — static verifier for plans, schedules, and compiled SPMD steps.

Layer 1 (:mod:`repro.analysis.rules`) lints the plan-chain artifacts —
traffic, routing table, exchange schedule, ragged plan, topology —
bundled in a :class:`~repro.analysis.context.PlanContext`; Layer 2
(:mod:`repro.analysis.traced`) lints the *traced* compiled
:class:`~repro.snn.distributed.DistributedSNN` step against what the
schedule says it should emit.  ``python -m repro.analysis`` runs the
seeded benchmark scenarios (see README "Static plan verification").
"""
from repro.analysis.context import PlanContext
from repro.analysis.rules import RULES, Finding, Rule, catalog, run_lints
from repro.analysis.traced import (
    count_collectives,
    expected_collectives,
    lint_traced_step,
    swap_recompile_hazard,
)

__all__ = [
    "PlanContext",
    "RULES",
    "Finding",
    "Rule",
    "catalog",
    "run_lints",
    "count_collectives",
    "expected_collectives",
    "lint_traced_step",
    "swap_recompile_hazard",
]
