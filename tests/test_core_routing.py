"""Unit + property tests for Algorithm 2 (two-level routing) and the
analytic latency model."""
from __future__ import annotations

import numpy as np
from tests._hypothesis_compat import given, settings, st

from repro.core import (
    connection_counts,
    device_graph,
    greedy_partition,
    level1_egress,
    level2_egress,
    p2p_routing,
    step_latency,
    table2_row,
    two_level_routing,
)
from repro.core.routing import group_pair_traffic


def _device_traffic(n=64, comm=8, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, comm, n)
    base = rng.random((n, n)) * 0.2
    boost = (labels[:, None] == labels[None, :]) * rng.random((n, n)) * 2.0
    t = base + boost
    t = (t + t.T) / 2
    np.fill_diagonal(t, 0.0)
    wg = rng.uniform(0.5, 2.0, n)
    return t, wg


class TestAlgorithm2:
    def test_table_valid(self):
        t, wg = _device_traffic()
        tb = two_level_routing(t, wg, 8)
        tb.validate()
        assert tb.n_groups == 8

    def test_share_sums_to_one(self):
        t, wg = _device_traffic()
        tb = two_level_routing(t, wg, 8)
        gpt = group_pair_traffic(tb)
        for gs in range(tb.n_groups):
            members = tb.group_of == gs
            for gd in range(tb.n_groups):
                if gs == gd or gpt[gs, gd] == 0:
                    continue
                assert np.isclose(tb.share[members, gd].sum(), 1.0)

    def test_route_paths(self):
        t, wg = _device_traffic()
        tb = two_level_routing(t, wg, 8)
        same = np.nonzero(tb.group_of == tb.group_of[0])[0]
        if same.size > 1:
            assert tb.route(same[0], same[1]) == [same[0], same[1]]
        other = np.nonzero(tb.group_of != tb.group_of[0])[0][0]
        path = tb.route(0, int(other))
        assert path[0] == 0 and path[-1] == other and len(path) <= 4

    def test_connection_reduction(self):
        t, wg = _device_traffic()
        c_p2p = connection_counts(p2p_routing(t, wg))
        c_two = connection_counts(two_level_routing(t, wg, 8))
        assert c_two.mean() < c_p2p.mean()

    def test_traffic_conservation(self):
        """Total level-2 egress equals total inter-group traffic."""
        t, wg = _device_traffic()
        tb = two_level_routing(t, wg, 8)
        cross = group_pair_traffic(tb).sum()
        assert np.isclose(level2_egress(tb).sum(), cross, rtol=1e-6)

    def test_level2_peak_balance(self):
        """Bridge splitting keeps peak within a few x of the mean."""
        t, wg = _device_traffic(n=96, comm=8)
        tb = two_level_routing(t, wg, 8)
        e2 = level2_egress(tb)
        carriers = e2[e2 > 0]
        assert carriers.max() <= 6 * carriers.mean()

    def test_auto_group_sweep(self):
        t, wg = _device_traffic(n=128)
        tb = two_level_routing(t, wg, None)
        tb.validate()
        assert 2 <= tb.n_groups <= 16

    @given(seed=st.integers(0, 30), g=st.sampled_from([4, 8, 16]))
    @settings(max_examples=10, deadline=None)
    def test_validity_property(self, seed, g):
        t, wg = _device_traffic(seed=seed)
        tb = two_level_routing(t, wg, g, seed=seed)
        tb.validate()
        assert (level2_egress(tb) >= 0).all()
        assert (level1_egress(tb) >= 0).all()


class TestLatencyModel:
    def test_two_level_faster_when_congested(self):
        t, wg = _device_traffic(n=96)
        lat_p2p = step_latency(p2p_routing(t, wg)).t_total
        lat_two = step_latency(two_level_routing(t, wg, 8)).t_total
        assert lat_two < lat_p2p

    def test_monotone_in_noise(self):
        t, wg = _device_traffic()
        row = table2_row(two_level_routing(t, wg, 8))
        assert all(b >= a for a, b in zip(row, row[1:]))

    def test_breakdown_positive(self):
        t, wg = _device_traffic()
        lb = step_latency(p2p_routing(t, wg))
        assert lb.t_total > 0 and lb.t_compute > 0
        assert lb.t_total >= lb.t_compute


class TestDeviceGraph:
    def test_aggregation(self, small_brain):
        g = small_brain.graph
        res = greedy_partition(g, 16)
        t, wg = device_graph(g, res.assign, 16)
        assert t.shape == (16, 16)
        assert np.allclose(t, t.T)
        assert np.allclose(np.diag(t), 0.0)
        # total device traffic equals total cut traffic
        assert np.isclose(t.sum() / 2, res.cut, rtol=1e-6)
        assert np.isclose(wg.sum(), g.weights.sum())
