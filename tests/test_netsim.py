"""netsim: topology routing, deterministic event simulation,
conservation invariants, and byte-exact replay of the repo's executed
exchange schedules (sparse ppermute rounds, ragged plans, Algorithm-2
tables, hierarchical all-to-all)."""
from __future__ import annotations

import numpy as np
import pytest

from repro import netsim
from repro.core import (
    ClusterModel,
    estimate,
    p2p_routing,
    step_latency,
    two_level_routing,
)
from repro.core.hierarchical import dispatch_bytes, dispatch_messages
from repro.snn import BlockSynapses, build_ragged_plan, exchange_volume
from tests.test_snn_sparse import _clustered_w


def _topos(n: int):
    pod = next(p for p in (4, 2, 1) if n % p == 0)
    out = [netsim.single_switch(n), netsim.ring(n)]
    if pod > 1:
        out += [netsim.two_tier(n, pod), netsim.fat_tree(n, pod)]
    return out


class TestTopology:
    @pytest.mark.parametrize("n", [4, 8, 12])
    def test_routes_are_connected_paths(self, n):
        """Every route chains src → ... → dst through consecutive links."""
        for topo in _topos(n):
            for s in range(n):
                for d in range(n):
                    path = topo.route(s, d)
                    if s == d:
                        assert path == ()
                        continue
                    assert len(path) >= 1
                    links = [topo.links[l] for l in path]
                    assert links[0].src == s and links[-1].dst == d
                    for a, b in zip(links, links[1:]):
                        assert a.dst == b.src

    def test_ring_takes_shorter_arc(self):
        topo = netsim.ring(8)
        assert len(topo.route(0, 3)) == 3
        assert len(topo.route(0, 5)) == 3  # counterclockwise
        assert len(topo.route(0, 4)) == 4  # tie → clockwise

    def test_two_tier_oversubscription_slows_spine(self):
        topo = netsim.two_tier(8, 4, dcn_oversub=4.0)
        up = topo.links[topo.params["leaf_up"][0]]
        nic = topo.links[topo.params["up"][0]]
        # uplink beta = oversub / (pod · bw): with oversub == pod they equal
        assert up.beta == pytest.approx(nic.beta)
        fast = netsim.two_tier(8, 4, dcn_oversub=1.0)
        assert fast.links[fast.params["leaf_up"][0]].beta < up.beta

    def test_config_schema_roundtrip(self):
        cfg = {"kind": "two_tier", "n_devices": 16, "pod_size": 4, "dcn_oversub": 2.0}
        topo = netsim.topology_from_config(cfg)
        assert topo.kind == "two_tier" and topo.n_devices == 16
        with pytest.raises(ValueError, match="unknown topology kind"):
            netsim.topology_from_config({"kind": "torus", "n_devices": 4})
        with pytest.raises(ValueError, match="pod_size"):
            netsim.two_tier(10, 4)

    def test_out_of_range_devices_rejected(self):
        topo = netsim.single_switch(4)
        with pytest.raises(ValueError, match="outside"):
            topo.route(0, 4)


class TestSimulate:
    def test_single_message_alpha_beta(self):
        """Latency of one uncontended message is exactly Σ_hops (α + B·β)."""
        topo = netsim.single_switch(4, link_bw=1e9, alpha=1e-6)
        res = netsim.simulate([[netsim.Message(0, 1, 1000)]], topo)
        res.assert_conserved()
        assert res.t_total == pytest.approx(2 * (1e-6 + 1000 / 1e9))

    def test_fifo_serialization_is_congestion(self):
        """Two messages sharing a NIC serialize (the second waits one
        link-serialization unit, then pipelines down its own hop); on
        disjoint NICs they run fully in parallel."""
        topo = netsim.single_switch(4, link_bw=1e9, alpha=0.0)
        unit = 1000 / 1e9  # per-link serialization of one message
        shared = netsim.simulate([[netsim.Message(0, 1, 1000), netsim.Message(0, 2, 1000)]], topo)
        disjoint = netsim.simulate([[netsim.Message(0, 1, 1000), netsim.Message(2, 3, 1000)]], topo)
        assert disjoint.t_total == pytest.approx(2 * unit)
        assert shared.t_total == pytest.approx(disjoint.t_total + unit)

    def test_alpha_msg_charged_once_at_injection(self):
        topo = netsim.single_switch(2, link_bw=1e9, alpha=0.0)
        base = netsim.simulate([[netsim.Message(0, 1, 0)]], topo)
        conn = netsim.simulate([[netsim.Message(0, 1, 0)]], topo, alpha_msg=5e-4)
        assert conn.t_total - base.t_total == pytest.approx(5e-4)

    def test_barriers_vs_pipelined(self):
        """Disjoint-device rounds overlap when pipelined and serialize
        under barriers."""
        topo = netsim.single_switch(4, link_bw=1e9, alpha=0.0)
        rounds = [
            [netsim.Message(0, 1, 1000)],
            [netsim.Message(2, 3, 1000, round=1)],
        ]
        piped = netsim.simulate(rounds, topo)
        barred = netsim.simulate(rounds, topo, barriers=True)
        assert piped.t_total == pytest.approx(barred.t_total / 2)
        # same-device rounds serialize at the NIC either way; pipelining
        # only saves the second message's store-and-forward overlap
        unit = 1000 / 1e9
        rounds2 = [
            [netsim.Message(0, 1, 1000)],
            [netsim.Message(0, 2, 1000, round=1)],
        ]
        piped2 = netsim.simulate(rounds2, topo)
        barred2 = netsim.simulate(rounds2, topo, barriers=True)
        assert piped2.t_total == pytest.approx(3 * unit)
        assert barred2.t_total == pytest.approx(4 * unit)

    def test_local_delivery_is_free(self):
        topo = netsim.single_switch(2)
        res = netsim.simulate([[netsim.Message(1, 1, 10**9)]], topo)
        res.assert_conserved()
        assert res.t_total == 0.0 and res.n_delivered == 1

    def test_deterministic_timelines(self):
        rng = np.random.default_rng(0)
        msgs = [
            netsim.Message(int(s), int(d), int(b))
            for s, d, b in zip(
                rng.integers(0, 8, 64),
                rng.integers(0, 8, 64),
                rng.integers(1, 10**6, 64),
            )
        ]
        topo = netsim.two_tier(8, 4)
        a = netsim.simulate([msgs], topo, collect_events=True)
        b = netsim.simulate([msgs], topo, collect_events=True)
        assert a.deliveries == b.deliveries
        assert a.t_total == b.t_total

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_conservation_random_schedules(self, seed):
        """Every injected message is delivered exactly once and the
        event queue drains — on every topology, multi-round, with
        self-messages and zero-byte messages mixed in."""
        rng = np.random.default_rng(seed)
        n = 8
        rounds = []
        for r in range(3):
            k = int(rng.integers(1, 40))
            rounds.append(
                [
                    netsim.Message(
                        int(rng.integers(0, n)),
                        int(rng.integers(0, n)),
                        int(rng.integers(0, 10**5)),
                        round=r,
                    )
                    for _ in range(k)
                ]
            )
        injected = sorted((m.src, m.dst, m.nbytes, m.round) for rnd in rounds for m in rnd)
        for topo in _topos(n):
            for barriers in (False, True):
                res = netsim.simulate(rounds, topo, barriers=barriers, collect_events=True)
                res.assert_conserved()
                delivered = sorted((d.src, d.dst, d.nbytes, d.round) for d in res.deliveries)
                assert delivered == injected, topo.name
                # link transit counts account exactly for every hop
                hops = sum(len(topo.route(m.src, m.dst)) for rnd in rounds for m in rnd)
                assert int(res.link_msgs.sum()) == hops


class TestScheduleReplay:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_sparse_and_flat_bytes_match_exchange_volume(self, seed):
        """Replayed bytes == exchange_volume for random masks, 1-D and
        (4, 2) meshes."""
        rng = np.random.default_rng(seed)
        n, bb = 8, 64
        mask = rng.random((n, n)) < 0.35
        np.fill_diagonal(mask, True)
        for mesh in [(n,), (4, 2)]:
            vol = exchange_volume(
                mask,
                mesh_shape=None if len(mesh) == 1 else mesh,
                block_bytes=bb,
            )
            sp = netsim.sparse_rounds(mask, mesh, bb)
            fl = netsim.flat_rounds(mesh, bb)
            assert netsim.total_bytes(sp) == vol["sparse"]
            assert netsim.total_bytes(fl) == vol["flat"]

    @pytest.mark.parametrize("seed", [3, 4, 5])
    def test_ragged_bytes_match_exchange_volume(self, seed):
        w = _clustered_w(64, 8, extra=((0, 1), (1, 3)), seed=seed)
        syn = BlockSynapses.from_dense(w, 8)
        plan = build_ragged_plan(syn, (4, 2))
        vol = exchange_volume(
            syn.mask(),
            mesh_shape=(4, 2),
            block_bytes=syn.block_size * 4,
            plan=plan,
        )
        rg = netsim.ragged_rounds(plan)
        assert netsim.total_bytes(rg) == vol["ragged"] == plan.bytes_per_step

    def test_replay_latency_ordering(self):
        """ragged ≤ sparse < flat on the switch-based fabrics — the
        gated netsim claim at test scale."""
        w = _clustered_w(64, 8)
        syn = BlockSynapses.from_dense(w, 8)
        bb = syn.block_size * 4
        plan = build_ragged_plan(syn, (4, 2))
        rounds = {
            "flat": netsim.flat_rounds((4, 2), bb),
            "sparse": netsim.sparse_rounds(syn.mask(), (4, 2), bb),
            "ragged": netsim.ragged_rounds(plan),
        }
        for topo in [netsim.single_switch(8), netsim.two_tier(8, 2),
                     netsim.fat_tree(8, 2)]:
            t = {}
            for name, rnds in rounds.items():
                res = netsim.simulate(rnds, topo, alpha_msg=2e-6)
                res.assert_conserved()
                t[name] = res.t_total
            assert t["ragged"] <= t["sparse"] < t["flat"], (topo.name, t)

    def test_a2a_rounds_match_dispatch_accounting(self):
        """Message counts and cross-pod bytes of the all-to-all replay
        equal the analytic dispatch accounting."""
        pods, inner, chunk = 3, 4, 128
        for two_level in (False, True):
            rounds = netsim.a2a_rounds(pods, inner, chunk, two_level=two_level)
            want = dispatch_messages(pods, inner, two_level=two_level)
            cross = sum(
                m.nbytes
                for rnd in rounds
                for m in rnd
                if m.src // inner != m.dst // inner
            )
            got_cross_msgs = sum(
                1
                for rnd in rounds
                for m in rnd
                if m.src // inner != m.dst // inner
            )
            assert got_cross_msgs == want["cross_pod"]
            wb = dispatch_bytes(pods, inner, chunk, two_level=two_level)
            assert cross == wb["cross_pod"]

    def test_two_level_a2a_wins_on_message_bound_fabric(self):
        """With a per-message cost, the bridge-aggregated all-to-all
        beats the flat one on the pod fabric (the Fig. 4 claim restated
        as simulated latency)."""
        topo = netsim.two_tier(12, 4)
        flat = netsim.simulate(
            netsim.a2a_rounds(3, 4, 64, two_level=False),
            topo,
            alpha_msg=1e-4,
            barriers=True,
        )
        two = netsim.simulate(
            netsim.a2a_rounds(3, 4, 64, two_level=True),
            topo,
            alpha_msg=1e-4,
            barriers=True,
        )
        flat.assert_conserved()
        two.assert_conserved()
        assert two.t_total < flat.t_total


class TestTableReplay:
    def _table(self, *, grouped: bool, seed: int = 0):
        rng = np.random.default_rng(seed)
        n = 12
        t = rng.random((n, n)) * (rng.random((n, n)) < 0.4)
        t = t + t.T
        np.fill_diagonal(t, 0.0)
        wg = np.ones(n)
        if grouped:
            return two_level_routing(t, wg, 3, grouping="greedy")
        return p2p_routing(t, wg)

    def test_p2p_single_round_per_connection(self):
        tb = self._table(grouped=False)
        rounds = netsim.table_rounds(tb, bytes_per_unit=100.0)
        assert len(rounds) == 1
        tm = tb.device_traffic
        n_conn = sum(1 for s, d in zip(tm.rows(), tm.indices) if s != d)
        assert len(rounds[0]) == n_conn

    def test_two_level_stages_and_conservation(self):
        tb = self._table(grouped=True)
        rounds = netsim.table_rounds(tb, bytes_per_unit=100.0)
        assert len(rounds) == 3
        tags = [{m.tag for m in rnd} for rnd in rounds]
        assert tags[0] <= {"level1"} and tags[1] <= {"level2"}
        assert tags[2] <= {"fanout"}
        # level-2 messages run bridge → bridge across groups
        for m in rounds[1]:
            assert tb.group_of[m.src] != tb.group_of[m.dst]
        # at most one message per connection (no duplicate (src, dst))
        for rnd in rounds:
            pairs = [(m.src, m.dst) for m in rnd]
            assert len(pairs) == len(set(pairs))
        res = netsim.simulate(rounds, netsim.single_switch(tb.n_devices), barriers=True)
        res.assert_conserved()

    def test_estimate_api_both_backends(self):
        tb = self._table(grouped=True)
        closed = estimate(tb, model="closed_form", noise=0.2)
        assert closed.t_total == step_latency(tb, noise=0.2).t_total
        sim = estimate(tb, model="netsim", noise=0.2)
        assert sim.t_total > sim.t_compute > 0
        assert 0 <= sim.worst_device < tb.n_devices
        with pytest.raises(ValueError, match="unknown latency model"):
            estimate(tb, model="exact")
        with pytest.raises(ValueError, match="devices"):
            estimate(tb, model="netsim", topology=netsim.single_switch(5))

    def test_estimate_netsim_monotone_in_noise(self):
        tb = self._table(grouped=True)
        cluster = ClusterModel(bytes_per_traffic_unit=1e6)
        ts = [
            estimate(tb, cluster, model="netsim", noise=z).t_total
            for z in (0.1, 0.3, 0.6)
        ]
        assert ts[0] < ts[1] < ts[2]


class TestWhatIf:
    def _plan(self):
        w = _clustered_w(64, 8, extra=((0, 1), (0, 2)))
        syn = BlockSynapses.from_dense(w, 8)
        return build_ragged_plan(syn, (4, 2))

    def test_sharding_degenerates_at_r1(self):
        """On a 1-D plan (R = 1) the sharded schedule IS the ragged one."""
        w = _clustered_w(32, 4)
        syn = BlockSynapses.from_dense(w, 4)
        plan = build_ragged_plan(syn, (4, 1))
        assert netsim.sharded_ragged_rounds(plan) == [
            [
                netsim.Message(m.src, m.dst, m.nbytes, m.round, "ragged_sharded")
                for m in rnd
            ]
            for rnd in netsim.ragged_rounds(plan)
        ]

    def test_sharded_bytes_only_grow_by_padding(self):
        plan = self._plan()
        base = netsim.total_bytes(netsim.ragged_rounds(plan))
        shard = netsim.total_bytes(netsim.sharded_ragged_rounds(plan))
        r = plan.mesh_shape[1]
        assert base <= shard <= base + sum(4 * (r - 1) * len(rnd.pairs) for rnd in plan.rounds)

    def test_wide_payloads_flip_the_verdict(self):
        """Sharding loses in the α-dominated regime and wins once
        payloads are wide (the ROADMAP question, answered by simulation)."""
        plan = self._plan()
        topos = {"fat_tree": netsim.fat_tree(8, 2)}
        narrow = netsim.payload_sharding_whatif(plan, topos, alpha_msg=2e-6, byte_scale=1.0)
        wide = netsim.payload_sharding_whatif(plan, topos, alpha_msg=2e-6, byte_scale=65536.0)
        assert wide["fat_tree"]["speedup"] > narrow["fat_tree"]["speedup"]
        assert wide["fat_tree"]["speedup"] > 1.0


class TestOutages:
    """Link-outage windows: stall vs reroute, conservation, blame."""

    def test_window_validation(self):
        with pytest.raises(ValueError, match="is empty"):
            netsim.LinkOutage(link=0, t_down=2e-6, t_up=1e-6)
        topo = netsim.single_switch(2)
        with pytest.raises(ValueError, match="unknown link"):
            netsim.simulate(
                [[netsim.Message(0, 1, 8)]],
                topo,
                outages=[netsim.LinkOutage(link=999, t_down=0.0, t_up=1e-6)],
            )

    def test_stall_when_no_backup_route(self):
        """single_switch has no redundancy: a downed NIC uplink stalls
        the transmission until t_up, exactly accounted, conserved."""
        topo = netsim.single_switch(2)
        up0 = topo.params["up"][0]
        msg = [[netsim.Message(0, 1, 64)]]
        base = netsim.simulate(msg, topo)
        t_up = 5e-5
        res = netsim.simulate(
            msg, topo, outages=[netsim.LinkOutage(link=up0, t_down=0.0, t_up=t_up)]
        )
        res.assert_conserved()
        assert res.n_rerouted == 0
        assert res.outage_stall_s == pytest.approx(t_up)
        assert res.t_total == pytest.approx(base.t_total + t_up)
        assert res.link_down_s[up0] == pytest.approx(t_up)

    def test_reroute_via_backup_spine(self):
        """fat_tree reroutes a cross-pod message around a downed uplink
        at injection: no stall, same latency (equal-cost backup), and
        the alternate spine's links carry the bytes."""
        topo = netsim.fat_tree(8, 2)
        src, dst = 0, 6  # cross-pod
        primary = topo.route(src, dst)
        leaf_up = primary[1]
        msg = [[netsim.Message(src, dst, 64)]]
        base = netsim.simulate(msg, topo)
        res = netsim.simulate(
            msg,
            topo,
            outages=[netsim.LinkOutage(link=leaf_up, t_down=0.0, t_up=1e-3)],
        )
        res.assert_conserved()
        assert res.n_rerouted == 1
        assert res.outage_stall_s == 0.0
        assert res.t_total == pytest.approx(base.t_total)
        assert res.link_bytes[leaf_up] == 0.0
        alt = topo.route_avoiding(src, dst, {leaf_up})
        assert alt is not None and leaf_up not in alt
        assert all(res.link_bytes[l] > 0 for l in alt)

    def test_in_flight_frame_drains(self):
        """A transmission that began before t_down completes — the
        window only blocks transmissions from *starting*."""
        topo = netsim.single_switch(2)
        up0 = topo.params["up"][0]
        lnk = topo.links[up0]
        mid = (lnk.alpha + 64 * lnk.beta) / 2  # window opens mid-frame
        msg = [[netsim.Message(0, 1, 64)]]
        base = netsim.simulate(msg, topo)
        res = netsim.simulate(
            msg,
            topo,
            outages=[netsim.LinkOutage(link=up0, t_down=mid, t_up=1.0)],
        )
        assert res.t_total == pytest.approx(base.t_total)
        assert res.outage_stall_s == 0.0

    def test_route_avoiding_per_kind(self):
        ss = netsim.single_switch(4)
        assert ss.route_avoiding(0, 1, {ss.route(0, 1)[0]}) is None
        rg = netsim.ring(6)
        other = rg.route_avoiding(0, 2, {rg.route(0, 2)[0]})
        assert other is not None
        assert rg.links[other[-1]].dst == 2  # reaches dst on the far arc
        ft = netsim.fat_tree(8, 2)
        pri = ft.route(0, 6)
        alt = ft.route_avoiding(0, 6, {pri[1]})
        assert alt is not None and pri[1] not in alt
        assert alt[0] == pri[0] and alt[-1] == pri[-1]  # same NICs
        # intra-pod routes never cross a spine: nothing to avoid with
        assert ft.route_avoiding(0, 1, {ft.route(0, 1)[0]}) is None
        # a route already clear of the avoid set is returned unchanged
        assert ft.route_avoiding(0, 6, {9999}) == pri

    def test_worst_device_availability_normalization(self):
        """Blame is busy-per-available-second: a device whose NIC was
        down most of the horizon but saturated while up outranks an
        equally-busy always-up device; with ``link_down_s=None``
        (results built before outages existed) the historical raw
        ranking is preserved."""
        import dataclasses as _dc

        topo = netsim.single_switch(3)
        up = topo.params["up"]
        # devices 0 and 1 send identical bytes to 2; device 1's NIC is
        # down for a long window first, so both raw busy times are equal
        # but device 1 had far less available time
        msgs = [[netsim.Message(0, 2, 512), netsim.Message(1, 2, 512)]]
        down = 5e-4
        res = netsim.simulate(
            msgs,
            topo,
            outages=[netsim.LinkOutage(link=up[1], t_down=0.0, t_up=down)],
        )
        assert res.link_busy_s[up[0]] == pytest.approx(res.link_busy_s[up[1]])
        assert res.worst_device() == 1
        legacy = _dc.replace(res, link_down_s=None)
        assert legacy.worst_device() == 0  # raw tie → first index
